"""Trainer: convergence, microbatch equivalence, EF gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.train.grad_compress import compress_grads, ef_init
from repro.train.optimizer import AdamW, cosine_schedule, global_norm
from repro.train.trainer import TrainConfig, make_train_step


def _setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    return cfg, m, params, data


def test_loss_decreases():
    cfg, m, params, data = _setup()
    train_step, opt = make_train_step(m, TrainConfig(lr=3e-3, warmup=5,
                                                     total_steps=40))
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    losses = []
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step, 8, 64).items()}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_microbatch_matches_full_batch_grad():
    """Accumulated-microbatch gradients == full-batch gradients."""
    cfg, m, params, data = _setup()
    from repro.train.trainer import make_loss_fn
    loss_fn = make_loss_fn(m, 0.0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0, 8, 32).items()}
    (_, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    halves = [jax.tree_util.tree_map(lambda x: x[i * 4:(i + 1) * 4], batch)
              for i in range(2)]
    gs = [jax.value_and_grad(loss_fn, has_aux=True)(params, h)[1]
          for h in halves]
    g_acc = jax.tree_util.tree_map(lambda a, b: (a + b) / 2, *gs)
    for a, b in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_grad_compress_error_feedback():
    """Residual is carried: two compressed steps recover what one step
    dropped (EF property: sum of deq == sum of raw up to last residual)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    ef = ef_init(g)
    d1, ef = compress_grads(g, ef)
    d2, ef = compress_grads(g, ef)
    total_deq = d1["w"] + d2["w"]
    total_raw = 2 * g["w"]
    resid = float(jnp.max(jnp.abs(total_deq + ef.error["w"] - total_raw)))
    assert resid < 1e-4
    # compression is actually lossy per-step
    assert float(jnp.max(jnp.abs(d1["w"] - g["w"]))) > 0


def test_adamw_decays_only_matrices():
    opt = AdamW(weight_decay=0.5, clip_norm=None)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_p, _, _ = opt.update(zero_g, state, params, jnp.asarray(0.1))
    assert float(jnp.max(jnp.abs(new_p["b"] - 1.0))) < 1e-6  # no decay
    assert float(jnp.max(new_p["w"])) < 1.0                   # decayed


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(100))) <= 0.11


def test_global_norm():
    t = {"a": jnp.ones((3,)) * 2.0}
    assert abs(float(global_norm(t)) - (12 ** 0.5)) < 1e-6
